"""Paper Figs 13, 14, 15: the three overhead experiments, on REAL wall-clock
execution of reduced-scale JAX services (not simulated).

- Fig 13 analog ("-rdynamic" vs base): JCT with kernel-ID construction ON
  vs OFF at dispatch time. Paper: -2.38%..+1.55% (noise). Our kernel ID is
  an aval hash — also expected to be noise-level.
- Fig 14 (FIKIT sharing stage vs base): single profiled service under the
  FIKIT engine vs direct execution. Paper: +0.09%..+4.93% (<5%).
- Fig 15 (measuring stage vs base): per-kernel timed exclusive runs vs
  direct execution. Paper: +34.5%..+71.8% (measurement is the expensive
  phase — which is WHY the two-phase design exists).
"""
from __future__ import annotations

import statistics as st
import time

import jax

from benchmarks.common import WALLCLOCK_ARCHS, Csv
from repro.config import get_config
from repro.core.client import HookClient
from repro.core.executor import WallClockEngine
from repro.core.profiler import ProfiledData, Profiler
from repro.core.scheduler import Mode
from repro.core.task import TaskKey
from repro.models import api
from repro.models.segmentation import SegmentedService

RUNS = 24
WARM = 6
ARCHS = WALLCLOCK_ARCHS[:5]


def _service(arch: str, host_gap=0.0008):
    cfg = get_config(arch).reduced()
    params = api.build_params(cfg, jax.random.key(0))
    # batch 8 x seq 64: per-segment kernels in the 1-5 ms range so python
    # dispatch noise is small relative to device time
    svc = SegmentedService(cfg, params, batch=8, seq=64, host_gap=host_gap)
    svc.warmup()
    svc.warmup()
    return cfg, svc


def _direct_jct(svc, runs=RUNS):
    """Base environment: run segments directly, no engine, no hooks."""
    jcts = []
    for _ in range(runs):
        state = svc.make_input()
        t0 = time.perf_counter()
        for seg in svc.segments:
            state = seg.fn(state)
            if seg.host_work is not None:
                state = seg.host_work(state)
        jcts.append(time.perf_counter() - t0)
    return st.median(jcts[WARM:])


def _engine_jct(svc, key, mode, profiled=None, identify=True, runs=RUNS,
                measured=False):
    with WallClockEngine(mode, profiled) as eng:
        cl = HookClient(eng, key, 0, svc.segments, identify=identify)
        jcts = []
        prof = Profiler(key)
        for _ in range(runs):
            state = svc.make_input()
            if measured:
                _, jct = cl.measure_run(state, prof)
            else:
                _, jct = cl.run(state)
            jcts.append(jct)
    return st.median(jcts[WARM:]), prof


def main(csvout=None):
    csvout = csvout or Csv(("name", "base_ms", "overhead_pct"))
    for arch in ARCHS:
        cfg, svc = _service(arch)
        key = TaskKey(cfg.name)
        base = _direct_jct(svc)

        # Fig 13: identification on vs off (sharing engine either way)
        with_id, _ = _engine_jct(svc, key, Mode.SHARING, identify=True)
        no_id, _ = _engine_jct(svc, key, Mode.SHARING, identify=False)
        csvout.add(f"fig13 ident_on_vs_off {arch}",
                   round(no_id * 1e3, 2),
                   round(100 * (with_id - no_id) / no_id, 2))

        # Fig 15: measuring stage vs base (also produces the profile)
        meas, prof = _engine_jct(svc, key, Mode.EXCLUSIVE, measured=True)
        csvout.add(f"fig15 measuring_vs_base {arch}", round(base * 1e3, 2),
                   round(100 * (meas - base) / base, 2))

        # Fig 14: FIKIT sharing stage (profiled) vs base
        pd = ProfiledData()
        pd.load(prof.statistics())
        fikit, _ = _engine_jct(svc, key, Mode.FIKIT, profiled=pd)
        csvout.add(f"fig14 sharing_stage_vs_base {arch}",
                   round(base * 1e3, 2),
                   round(100 * (fikit - base) / base, 2))
    csvout.emit("Fig13/14/15: interception, sharing-stage and "
                "measuring-stage overheads (wall clock)")
    return csvout


if __name__ == "__main__":
    main()
