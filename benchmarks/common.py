"""Shared benchmark infrastructure.

Task traces for the scheduling-policy benchmarks are synthesized from each
architecture's metadata (layer count, widths) so kernel durations reflect
relative per-layer compute, scaled into the paper's ms regime. High-priority
services model interactive inference (sync client, real host gaps from
sampling/tokenization); low-priority services model batch jobs (async
clients, device-bound).
"""
from __future__ import annotations

import csv
import sys
from typing import Dict, List, Tuple

from repro.config import ModelConfig, get_config
from repro.core.kernel_id import KernelID
from repro.core.scheduler import Mode, SimScheduler
from repro.core.task import TaskKey, TaskSpec, TraceKernel

# paper Fig 16's A..J pairings, mapped onto our assigned pool
PAIRS: List[Tuple[str, str, str]] = [
    ("A", "qwen3-4b", "mamba2-2.7b"),
    ("B", "qwen3-4b", "granite-20b"),
    ("C", "deepseek-v2-236b", "recurrentgemma-9b"),
    ("D", "deepseek-v2-236b", "mamba2-2.7b"),
    ("E", "qwen3-4b", "recurrentgemma-9b"),
    ("F", "stablelm-1.6b", "h2o-danube-3-4b"),
    ("G", "llama4-scout-17b-a16e", "mamba2-2.7b"),
    ("H", "llama4-scout-17b-a16e", "qwen3-4b"),
    ("I", "llama4-scout-17b-a16e", "granite-20b"),
    ("J", "seamless-m4t-medium", "llava-next-mistral-7b"),
]

# wall-clock subset (paper used 7 torchvision models)
WALLCLOCK_ARCHS = ["stablelm-1.6b", "qwen3-4b", "mamba2-2.7b",
                   "recurrentgemma-9b", "h2o-danube-3-4b",
                   "seamless-m4t-medium", "llava-next-mistral-7b"]

TIME_SCALE = 4e-13  # scales synthetic "flops" into seconds


def _layer_cost(cfg: ModelConfig) -> float:
    D = cfg.d_model
    ff = cfg.resolved_moe_d_ff * cfg.top_k if cfg.num_experts else cfg.d_ff
    if cfg.family == "ssm":
        ff = 2 * cfg.ssm_d_inner
    attn = 4 * D * D if cfg.num_heads else 3 * D * cfg.ssm_d_inner
    return (attn + 3 * D * max(ff, D)) * 1.0


def arch_trace(arch: str, *, priority: int, interactive: bool,
               seq_tokens: int = 64, time_scale: float = TIME_SCALE,
               arrival: float = 0.0) -> TaskSpec:
    """One inference invocation of ``arch`` as a kernel trace.

    Interactive services have real host gaps (tokenize/sample between
    dispatches) and a synchronous client; batch services are device-bound
    async clients with negligible gaps. Kernel times land in the paper's
    0.1-20 ms regime."""
    cfg = get_config(arch)
    L = cfg.num_layers
    layer_t = _layer_cost(cfg) * seq_tokens * time_scale
    embed_t = cfg.vocab_size * cfg.d_model * seq_tokens * 0.05 * time_scale
    kernels = [TraceKernel(KernelID(f"{arch}/embed"), embed_t,
                           0.0015 if interactive else 0.00005)]
    gap = (0.004 if interactive else 0.00004)
    kid = KernelID(f"{arch}/layer", (L,), (cfg.d_model,))
    for _ in range(L):
        kernels.append(TraceKernel(kid, layer_t, gap))
    head_t = cfg.vocab_size * cfg.d_model * seq_tokens * time_scale
    kernels.append(TraceKernel(KernelID(f"{arch}/head"), head_t,
                               0.006 if interactive else 0.0001))
    return TaskSpec(TaskKey(arch, (seq_tokens,)), priority, kernels,
                    arrival=arrival,
                    max_inflight=1 if interactive else 16)


def continuous_stream(spec: TaskSpec, n: int, inter_task_gap: float = 0.004
                      ) -> TaskSpec:
    """Model a service that runs tasks continuously as ONE long kernel
    stream: n back-to-back invocations with a host gap between them. The
    stream is a single scheduler task (single holder), so its inter-kernel
    gaps are schedulable by FIKIT throughout."""
    kernels = []
    for i in range(n):
        ks = list(spec.kernels)
        if i < n - 1:
            last = ks[-1]
            ks[-1] = TraceKernel(last.kid, last.duration, inter_task_gap)
        kernels.extend(ks)
    return TaskSpec(spec.key, spec.priority, kernels, arrival=spec.arrival,
                    max_inflight=spec.max_inflight)


def repeat_task(spec: TaskSpec, n: int, interval: float,
                start: float = 0.0) -> List[TaskSpec]:
    """n task instances issued every ``interval`` seconds (0 = back-to-back
    handled by the scheduler client model)."""
    out = []
    for i in range(n):
        out.append(TaskSpec(spec.key, spec.priority, spec.kernels,
                            arrival=start + i * interval,
                            max_inflight=spec.max_inflight))
    return out


def run_modes(tasks: List[TaskSpec], profiled, modes=(Mode.SHARING,
              Mode.EXCLUSIVE, Mode.FIKIT, Mode.PREEMPT),
              jitter: float = 0.03,
              seed: int = 0) -> Dict[Mode, object]:
    return {m: SimScheduler(tasks, m, profiled, jitter=jitter,
                            seed=seed).run() for m in modes}


class Csv:
    """Collects rows keyed by name and prints CSV; rows shorter than the
    header are right-padded so multi-column benches stay well-formed."""

    def __init__(self, header=("name", "us_per_call", "derived")):
        self.rows = []
        self.header = header

    def add(self, name, *cols):
        self.rows.append((name,) + cols)

    def emit(self, title: str):
        print(f"# {title}")
        w = csv.writer(sys.stdout)
        w.writerow(self.header)
        for r in self.rows:
            w.writerow(tuple(r) + ("",) * max(0, len(self.header) - len(r)))
        print()
