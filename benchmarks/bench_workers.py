"""Multi-process worker fleet: aggregate goodput scaling 1 -> 2 workers.

FIKIT's cloud framing ("always more task requests than the number of
GPU available") makes the single engine process the bottleneck; this
bench proves the worker plane actually buys throughput. An identical
store of wall-paced jobs (every kernel completion sleeps ``PACE_S`` —
the stand-in for real device work, large against the ~50us SQLite
write) is drained by a 1-worker and then a 2-worker fleet via
``WorkerSupervisor``. Measured from the supervisor's go-gate (workers
register first, so interpreter start-up is excluded):

- **aggregate goodput** (kernels/s across the fleet) must scale
  >= 1.6x from 1 to 2 workers (``min_goodput_scaling_2w``);
- **gold p99 protection**: claims are strict-priority, so the gold
  class's p99 completion latency at 2 workers must not regress past
  ``max_gold_p99_ratio_2w_vs_1w`` of the 1-worker fleet's;
- **zero lease churn**: a healthy fleet never lets a heartbeat lapse
  (``max_lease_churn``).

Gates tracked in BENCH_workers.json, enforced by
``scripts/check_bench_gates.py``. Set BENCH_SMOKE=1 (CI) for a smaller
job count.
"""
from __future__ import annotations

import os
import tempfile
import time

from benchmarks.common import Csv
from repro.core.jobstore import DONE, JobStore
from repro.core.kernel_id import KernelID
from repro.core.scheduler import profile_tasks
from repro.core.task import TaskKey, TaskSpec, TraceKernel
from repro.serving.workers import (WorkerSupervisor, enqueue_specs,
                                   fleet_status)

SMOKE = bool(int(os.environ.get("BENCH_SMOKE", "0")))

JOBS = 24 if SMOKE else 48
KERNELS_PER_JOB = 8
PACE_S = 0.003
GOLD_SHARE = 0.25
BATCH = 4


def _specs():
    out = []
    for i in range(JOBS):
        gold = i % int(1 / GOLD_SHARE) == 0
        kid = KernelID(f"{'gold' if gold else 'bronze'}{i}/k")
        out.append(TaskSpec(TaskKey(f"svc{i}", ()), 0 if gold else 5,
                            [TraceKernel(kid, 0.002, 0.0005)]
                            * KERNELS_PER_JOB))
    return out


def _populate(path: str) -> None:
    specs = _specs()
    with JobStore(path) as store:
        enqueue_specs(store, specs,
                      qos=lambda s: "gold" if s.priority == 0
                      else "bronze")
        store.snapshot_profiles(profile_tasks(specs, T=2, jitter=0.0,
                                              measurement_overhead=0.0))
        store.checkpoint()


def _run_fleet(n: int, tmp: str) -> dict:
    """Drain a fresh identical store with an n-worker fleet; returns
    wall/goodput/gold-latency stats measured from the go-gate."""
    path = os.path.join(tmp, f"fleet_{n}.db")
    _populate(path)
    sup = WorkerSupervisor(path, n=n, pace_s=PACE_S, batch=BATCH,
                           lease_s=10.0, heartbeat_s=0.5)
    sup.start()
    try:
        summaries = sup.wait(timeout=600.0)
    finally:
        sup.kill()
    with JobStore(path) as store:
        recs = store.jobs()
        fs = fleet_status(store)
    done = [r for r in recs if r.state == DONE]
    assert len(done) == JOBS, f"{len(done)}/{JOBS} jobs done"
    wall = max(r.updated_at for r in done) - sup.t_go
    gold_lat = sorted(r.updated_at - sup.t_go for r in done
                      if r.qos == "gold")
    p99 = gold_lat[min(len(gold_lat) - 1,
                       int(round(0.99 * (len(gold_lat) - 1))))]
    kernels = sum(s["kernels_done"] for s in summaries)
    return {"workers": n, "wall_s": round(wall, 4),
            "kernels": kernels,
            "goodput_kps": round(kernels / wall, 2),
            "gold_jobs": len(gold_lat),
            "gold_p99_s": round(p99, 4),
            "lease_churn": fs["lease_churn"]}


def main() -> Csv:
    csvout = Csv(header=("name", "value", "derived"))
    tmp = tempfile.mkdtemp(prefix="fikit_bench_workers_")
    fleets = {}
    try:
        for n in (1, 2):
            t0 = time.perf_counter()
            fleets[str(n)] = _run_fleet(n, tmp)
            fleets[str(n)]["bench_wall_s"] = round(
                time.perf_counter() - t0, 2)
    finally:
        import shutil
        shutil.rmtree(tmp, ignore_errors=True)

    f1, f2 = fleets["1"], fleets["2"]
    scaling = round(f2["goodput_kps"] / f1["goodput_kps"], 3)
    gold_ratio = round(f2["gold_p99_s"] / f1["gold_p99_s"], 3)
    churn = f1["lease_churn"] + f2["lease_churn"]
    for key, f in fleets.items():
        csvout.add(f"goodput_kps_{key}w", f["goodput_kps"],
                   f"{f['kernels']}k in {f['wall_s']}s")
        csvout.add(f"gold_p99_s_{key}w", f["gold_p99_s"],
                   f"{f['gold_jobs']} gold jobs")
    csvout.add("goodput_scaling_2w_vs_1w", scaling, "gate >= 1.6")
    csvout.add("gold_p99_ratio_2w_vs_1w", gold_ratio, "gate <= 1.15")
    csvout.add("lease_churn_total", churn, "gate == 0")
    csvout.emit("Worker fleet: aggregate goodput scaling with gold p99 "
                "protection")
    csvout.json_payload = {
        "smoke": SMOKE,
        "jobs": JOBS,
        "kernels_per_job": KERNELS_PER_JOB,
        "pace_ms": 1e3 * PACE_S,
        "gold_share": GOLD_SHARE,
        "fleets": fleets,
        "scaling": {
            "goodput_scaling_2w_vs_1w": scaling,
            "gold_p99_ratio_2w_vs_1w": gold_ratio,
            "lease_churn_total": churn,
        },
    }
    return csvout


if __name__ == "__main__":
    main()
