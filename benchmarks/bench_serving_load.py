"""Serving-under-load: open-loop Poisson + diurnal replay through the
admission plane, per-QoS-class latency and goodput under 2x overload.

Three QoS classes (gold/silver/bronze -> FIKIT Q0/Q2/Q5) front one
wall-clock engine running sleep-payload services. The load is OPEN-LOOP
(arrivals never wait on completions — the regime the closed-loop
``invoke_concurrent`` path cannot produce), replayed from pre-drawn
schedules:

1. **calibrate** — closed-loop exclusive invocations measure the group
   service time; rates below are derived from it so the bench self-tunes
   to the machine, and the measured JCT primes the plane's EMA so SLO
   shedding is informed from the first request.
2. **underload** (0.5x capacity, Poisson, batch-1 accounting) — the
   per-class latency baseline.
3. **overload** (2x capacity even with full continuous batching;
   Poisson gold/silver + diurnal bronze) — where admission control
   earns its keep: gold stays fast and in-SLO, silver sheds what its
   deadline can't meet, bronze absorbs rejects via backpressure.

Reported per phase: per-class offered/admitted/rejected/shed counts,
p50/p99/mean latency, goodput; plus the feeder's worst lag (so a slow
feeder can't masquerade as a fast plane). Derived gate inputs:

- ``hi_p99_overload_ratio`` — gold p99 under overload vs underload; the
  whole point of QoS classes is that this stays bounded while total
  offered load quadruples.
- ``hi_goodput_overload`` — fraction of offered gold requests that
  completed within their SLO under overload.
- ``shed_ordering_ok`` — priority_inversions == 0 AND every admit
  happened with zero requests queued in any higher class.
- ``conservation_ok`` — per class, offered == admitted + rejected +
  shed + requeued in every phase.
- ``admission_off_trace_identical`` — the wired-but-disabled plane
  produced a policy decision trace bit-identical (after instance-id
  normalization) to the no-plane direct ``invoke`` path.

Gates (tracked in BENCH_serving_load.json, enforced by
``scripts/check_bench_gates.py``): ``max_hi_p99_overload_ratio``,
``min_hi_goodput``, ``require_shed_ordering``,
``require_admission_off_trace_identical``, ``require_conservation``.

Set BENCH_SMOKE=1 (CI) for a few-thousand-request replay; the full run
(nightly) replays >= 10^5 requests.
"""
from __future__ import annotations

import os
import random
import statistics
import time

from benchmarks.common import Csv
from repro.core.client import HookClient
from repro.core.kernel_id import KernelID
from repro.core.scheduler import Mode
from repro.core.task import TaskKey
from repro.serving import QoSClass, ServingSystem
from repro.serving.loadgen import (diurnal_arrivals, merge_schedules,
                                   poisson_arrivals, replay)

SMOKE = bool(int(os.environ.get("BENCH_SMOKE", "0")))

KERNEL_S = 0.0005          # per-kernel sleep payload
SEGMENTS = 2               # kernels per invocation
N_UNDER = 600 if SMOKE else 5_000
N_OVER = 3_000 if SMOKE else 100_000
UNDER_FACTOR = 0.5         # of batch-1 capacity
OVERLOAD_FACTOR = 2.0      # of full-batch capacity
SPLIT = {"gold": 0.10, "silver": 0.30, "bronze": 0.60}
MAX_INFLIGHT = 4
SEED = 7


class _SleepSvc:
    """Duck-typed InferenceService: each segment sleeps KERNEL_S."""

    class _Seg:
        def __init__(self, name, dur):
            self.name = name
            self.dur = dur
            self.host_work = None

        def fn(self, state):
            time.sleep(self.dur)
            return state

        def kernel_id(self, state):
            return KernelID(self.name)

    class _Svc:
        def __init__(self, segs):
            self.segments = segs

        def make_input(self):
            return 0

    def __init__(self, name, priority, dur=KERNEL_S, n=SEGMENTS):
        self.key = TaskKey(name)
        self.priority = priority
        self.svc = self._Svc([self._Seg(f"{name}/s{i}", dur)
                              for i in range(n)])

    def client(self, engine, identify=True):
        return HookClient(engine, self.key, self.priority,
                          self.svc.segments, identify=identify)


def _calibrate(svcs) -> float:
    """Median closed-loop group service time, exclusive occupancy."""
    jcts = []
    with ServingSystem(Mode.FIKIT) as sys_:
        for svc in svcs.values():
            jcts.extend(sys_.invoke(svc, n=10 if SMOKE else 20))
    return statistics.median(jcts)


def _classes(group_time: float):
    gold_dl = max(0.25, 150 * group_time)
    silver_dl = max(0.10, 50 * group_time)
    return (QoSClass("gold", priority=0, queue_limit=64,
                     deadline=gold_dl, max_batch=4),
            QoSClass("silver", priority=2, queue_limit=256,
                     deadline=silver_dl, max_batch=8),
            QoSClass("bronze", priority=5, queue_limit=1024,
                     deadline=None, max_batch=16))


def _run_phase(svcs, classes, group_time, schedule, record_events):
    """Replay one schedule open-loop against a fresh system; returns
    (admission stats, replay report, events)."""
    with ServingSystem(Mode.FIKIT,
                       admission={"classes": classes,
                                  "max_inflight": MAX_INFLIGHT,
                                  "record_events": record_events}) as sys_:
        for svc in svcs.values():
            sys_.admission.note_latency(svc, group_time)
        rep = replay(sys_.admission, schedule, keep_tickets=False)
        sys_.admission.drain(timeout=120)
        stats = sys_.admission.stats()
        events = list(sys_.admission.events)
    return stats, rep, events


def _normalized(trace):
    mapping = {}
    out = []
    for ev in trace:
        ev = tuple(ev)
        if len(ev) > 1 and isinstance(ev[1], int):
            ev = (ev[0], mapping.setdefault(ev[1], len(mapping))) + ev[2:]
        out.append(ev)
    return out


def _trace_differential() -> bool:
    """Admission OFF must be bit-identical to direct invoke (the
    contract the admission plane ships under)."""
    pattern = ["a", "b", "a", "a", "b"]

    def direct():
        svcs = {"a": _SleepSvc("a", 0, dur=0.0), "b": _SleepSvc("b", 5,
                                                                dur=0.0)}
        with ServingSystem(Mode.FIKIT) as sys_:
            for name in pattern:
                sys_.invoke(svcs[name], n=1)
            return _normalized(list(sys_.engine.policy.trace))

    def disabled_plane():
        svcs = {"a": _SleepSvc("a", 0, dur=0.0), "b": _SleepSvc("b", 5,
                                                                dur=0.0)}
        qos = {"a": "gold", "b": "bronze"}
        with ServingSystem(Mode.FIKIT,
                           admission={"enabled": False}) as sys_:
            for name in pattern:
                sys_.submit_async(svcs[name], qos[name])
            return _normalized(list(sys_.engine.policy.trace))

    return direct() == disabled_plane()


def _conservation_ok(stats) -> bool:
    return all(s["offered"] == (s["admitted"] + s["rejected"]
                                + s["shed"] + s["requeued"])
               for s in stats["classes"].values())


def main():
    rng = random.Random(SEED)
    svcs = {"gold": _SleepSvc("interactive", 0),
            "silver": _SleepSvc("standard", 2),
            "bronze": _SleepSvc("batch", 5)}
    group_time = _calibrate(svcs)
    classes = _classes(group_time)

    # full-batch group demand per offered request: sum over classes of
    # share/max_batch — the stability accounting that makes 2x a REAL
    # overload even after continuous batching does its best
    batch_weight = sum(SPLIT[c.name] / c.max_batch for c in classes)
    r_under = UNDER_FACTOR / group_time                 # batch-1 capacity
    r_over = OVERLOAD_FACTOR / (batch_weight * group_time)
    d_under = N_UNDER / r_under
    d_over = N_OVER / r_over

    under_sched = merge_schedules(*[
        poisson_arrivals(r_under * SPLIT[name], d_under, svcs[name], name,
                         rng)
        for name in SPLIT])
    over_sched = merge_schedules(
        poisson_arrivals(r_over * SPLIT["gold"], d_over, svcs["gold"],
                         "gold", rng),
        poisson_arrivals(r_over * SPLIT["silver"], d_over, svcs["silver"],
                         "silver", rng),
        diurnal_arrivals(r_over * SPLIT["bronze"], d_over, svcs["bronze"],
                         "bronze", rng, depth=0.5))

    under, under_rep, _ = _run_phase(svcs, classes, group_time,
                                     under_sched, record_events=False)
    over, over_rep, over_events = _run_phase(svcs, classes, group_time,
                                             over_sched,
                                             record_events=True)

    eps = 1e-9
    hi_ratio = (over["classes"]["gold"]["p99_ms"]
                / max(under["classes"]["gold"]["p99_ms"], eps))
    admits = [e for e in over_events if e[1] == "admit"]
    shed_ordering_ok = (over["priority_inversions"] == 0
                        and all(e[4] == 0 for e in admits))
    trace_identical = _trace_differential()

    csv = Csv(("name", "value", "derived"))
    csv.add("group_time_ms", round(1e3 * group_time, 4))
    csv.add("offered_under", under_rep.offered,
            f"{r_under:.0f} rps over {d_under:.1f}s")
    csv.add("offered_over", over_rep.offered,
            f"{r_over:.0f} rps over {d_over:.1f}s")
    for phase, stats in (("under", under), ("over", over)):
        for cname, s in stats["classes"].items():
            csv.add(f"{phase}_{cname}_p99_ms", round(s["p99_ms"], 3),
                    f"p50 {s['p50_ms']:.3f}ms goodput {s['goodput']:.3f} "
                    f"shed {s['shed']} rejected {s['rejected']}")
    csv.add("hi_p99_overload_ratio", round(hi_ratio, 3))
    csv.add("hi_goodput_overload",
            round(over["classes"]["gold"]["goodput"], 4))
    csv.add("shed_ordering_ok", shed_ordering_ok,
            f"priority_inversions {over['priority_inversions']}")
    csv.add("admission_off_trace_identical", trace_identical)
    csv.add("feeder_lag_max_ms",
            round(1e3 * max(under_rep.lag_max_s, over_rep.lag_max_s), 2))
    csv.emit("serving load (open-loop, admission plane)")

    csv.json_payload = {
        "smoke": SMOKE,
        "group_time_ms": 1e3 * group_time,
        "max_inflight": MAX_INFLIGHT,
        "overload_factor": OVERLOAD_FACTOR,
        "class_spec": {c.name: {"priority": c.priority,
                                "queue_limit": c.queue_limit,
                                "deadline_s": c.deadline,
                                "max_batch": c.max_batch}
                       for c in classes},
        "underload": {"offered": under_rep.offered,
                      "rate_rps": r_under,
                      "wall_s": under_rep.wall_s,
                      "lag_max_s": under_rep.lag_max_s,
                      "classes": under["classes"]},
        "overload": {"offered": over_rep.offered,
                     "rate_rps": r_over,
                     "wall_s": over_rep.wall_s,
                     "lag_max_s": over_rep.lag_max_s,
                     "priority_inversions": over["priority_inversions"],
                     "classes": over["classes"]},
        "hi_p99_overload_ratio": hi_ratio,
        "hi_goodput_overload": over["classes"]["gold"]["goodput"],
        "shed_ordering_ok": shed_ordering_ok,
        "conservation_ok": (_conservation_ok(under)
                            and _conservation_ok(over)),
        "admission_off_trace_identical": trace_identical,
    }
    return csv


if __name__ == "__main__":
    main()
