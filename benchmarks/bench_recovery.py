"""Ops-plane durability costs: crash-recovery latency vs store size, and
hi-priority JCT disturbance under a low-priority cancel storm.

Part 1 — **recovery sweep**: populate a file-backed ``JobStore`` with N
incomplete jobs (each mid-stream: specs + partial completion watermarks +
a profile snapshot), then time the full cold-restart path — reopen the
store, build the recovery plan, reload the learned profiles, and
construct the recovered ``SimScheduler``. Reported as per-job
microseconds per store size; the gate bounds the worst per-job cost and
its growth from the smallest to the largest store (recovery must stay
~linear in store size, i.e. per-job cost ~flat).

Part 2 — **cancel storm**: a high-priority interactive task shares the
device with a pool of low-priority fillers; mid-run, every filler is
cancelled through scripted ``FaultPlan`` controls at consecutive kernel
boundaries. The hi task's JCT with the storm is compared against the
identical run without it (same store attached in both). Cancellation
purges parked requests at kernel boundaries only, so the disturbance
ceiling is tight (``max_cancel_storm_hi_jct_ratio``).

Gates (tracked in BENCH_recovery.json, enforced by
``scripts/check_bench_gates.py``): ``max_recovery_us_per_job``,
``max_recovery_growth``, ``max_cancel_storm_hi_jct_ratio``.

Set BENCH_SMOKE=1 (CI) for reduced store sizes.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time

from benchmarks.common import Csv
from repro.core.faults import FaultPlan
from repro.core.jobstore import JobStore, spec_to_obj
from repro.core.kernel_id import KernelID
from repro.core.scheduler import Mode, SimScheduler, profile_tasks
from repro.core.task import TaskKey, TaskSpec, TraceKernel

SMOKE = bool(int(os.environ.get("BENCH_SMOKE", "0")))

STORE_SIZES = (50, 200) if SMOKE else (100, 400, 1600)
STORM_FILLERS = 6 if SMOKE else 12


def _job_spec(i: int, nk: int = 8) -> TaskSpec:
    kid = KernelID(f"svc{i % 16}/k")
    return TaskSpec(TaskKey(f"svc{i % 16}", (i,)), i % 10,
                    [TraceKernel(kid, 0.002, 0.001)] * nk)


def _populate(path: str, n_jobs: int) -> None:
    with JobStore(path) as store:
        for i in range(n_jobs):
            s = _job_spec(i)
            jid = store.record_submit(None, s.key, s.priority,
                                      n_kernels=len(s.kernels),
                                      spec=spec_to_obj(s))
            for seq in range(i % len(s.kernels)):   # mid-stream watermark
                store.record_completion(jid, seq)
        store.snapshot_profiles(
            profile_tasks([_job_spec(i) for i in range(16)], T=2,
                          jitter=0.0, measurement_overhead=0.0))
        store.checkpoint()


def _time_recovery(path: str, reps: int = 3) -> float:
    """Cold-restart wall time (us): reopen + plan + profile reload +
    recovered-scheduler construction. Best of ``reps``."""
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        store = JobStore(path)
        sim = SimScheduler.recover(store, Mode.FIKIT)
        t1 = time.perf_counter()
        assert sim.tasks, "recovery plan was empty"
        store.close()
        best = min(best, t1 - t0)
    return best * 1e6


def _storm_workload():
    hi = TaskSpec(TaskKey("hi"), 0,
                  [TraceKernel(KernelID("hi/a"), 0.002, 0.005)] * 12)
    los = [TaskSpec(TaskKey(f"lo{i}"), 5 + i % 5,
                    [TraceKernel(KernelID(f"lo{i}/a"), 0.0015, 0.0003)] * 10,
                    arrival=0.0005 * (i + 1))
           for i in range(STORM_FILLERS)]
    return [hi] + los


def _storm_run(cancel: bool) -> float:
    specs = _storm_workload()
    pd = profile_tasks(specs, T=2, jitter=0.0, measurement_overhead=0.0)
    controls = {}
    if cancel:
        # one filler cancelled per boundary, a burst starting mid-run
        for i in range(STORM_FILLERS):
            controls[8 + i] = [("cancel", 1 + i)]
    with JobStore.memory() as store:
        sim = SimScheduler(specs, Mode.FIKIT, pd, jobstore=store,
                           fault_plan=FaultPlan(controls=controls))
        rep = sim.run()
        if cancel:
            assert len(sim.cancelled) == STORM_FILLERS
        return rep.jct(0)


def main() -> Csv:
    csvout = Csv(header=("name", "value", "derived"))
    tmp = tempfile.mkdtemp(prefix="fikit_bench_recovery_")
    per_job_us = {}
    try:
        for n in STORE_SIZES:
            path = os.path.join(tmp, f"store_{n}.db")
            _populate(path, n)
            total_us = _time_recovery(path)
            per_job_us[str(n)] = round(total_us / n, 2)
            csvout.add(f"recovery_total_us_n{n}", round(total_us, 1),
                       f"{per_job_us[str(n)]}us/job")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    smallest, largest = str(STORE_SIZES[0]), str(STORE_SIZES[-1])
    growth = round(per_job_us[largest] / per_job_us[smallest], 3)

    hi_plain = _storm_run(cancel=False)
    hi_storm = _storm_run(cancel=True)
    storm_ratio = round(hi_storm / hi_plain, 4)
    csvout.add("cancel_storm_hi_jct_ratio", storm_ratio,
               f"{1e3 * hi_storm:.2f}ms vs {1e3 * hi_plain:.2f}ms")
    csvout.add("recovery_growth_vs_smallest", growth,
               f"{smallest}->{largest} jobs")

    csvout.emit("Ops plane: crash-recovery latency vs store size + "
                "hi-JCT disturbance under a lo cancel storm")
    csvout.json_payload = {
        "smoke": SMOKE,
        "store_sizes": list(STORE_SIZES),
        "recovery_sweep": {
            "per_job_us": per_job_us,
            "growth_vs_smallest": growth,
            "size_ratio": STORE_SIZES[-1] / STORE_SIZES[0],
        },
        "cancel_storm": {
            "fillers": STORM_FILLERS,
            "hi_jct_ms_no_storm": round(1e3 * hi_plain, 3),
            "hi_jct_ms_storm": round(1e3 * hi_storm, 3),
            "hi_jct_ratio_vs_no_storm": storm_ratio,
        },
    }
    return csvout


if __name__ == "__main__":
    main()
