"""Roofline analysis (deliverable g): per (arch x shape) on the single-pod
mesh, derive the three roofline terms from the compiled dry-run artifact:

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s      (197 TF bf16)
    memory term     = HLO_bytes_per_device / HBM_bw           (819 GB/s)
    collective term = collective_bytes_per_device / ICI_bw    (~50 GB/s/link)

``cost_analysis()`` and the parsed HLO are PER-DEVICE programs, so the
"/(chips x ...)" division in the assignment's formulas is already applied.
Also reports MODEL_FLOPS (6*N*D train / 2*N*D inference, N_active for MoE)
and the useful-compute ratio MODEL_FLOPS / (HLO_FLOPs x chips), which
catches remat/capacity/dispatch waste.

Reads dryrun_results.json produced by ``repro.launch.dryrun --all --out``.
"""
from __future__ import annotations

import json
import math
import os

import jax

from benchmarks.common import Csv
from repro.config import MOE, SHAPES, get_config
from repro.launch.hlo_cost import resource_class_from_cost
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

#: arch ridge point (FLOP/byte): programs above run compute-bound
RIDGE = PEAK_FLOPS_BF16 / HBM_BW

RESULTS = os.environ.get("DRYRUN_RESULTS",
                         os.path.join(os.path.dirname(__file__), "..",
                                      "dryrun_results.json"))


def param_counts(cfg):
    """(total_params, active_params) from the SDS tree (no allocation)."""
    from repro.models import api
    tree = api.build_params(cfg, key=None)
    total = 0
    routed = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        n = math.prod(leaf.shape)
        total += n
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if name in ("w1", "w2", "w3"):
            routed += n
    if cfg.family == MOE and cfg.num_experts:
        active = total - routed + routed * cfg.top_k / cfg.num_experts
    else:
        active = total
    return total, active


def model_flops(cfg, shape) -> float:
    _, active = param_counts(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens
    tokens = shape.global_batch          # ONE token per sequence
    return 2.0 * active * tokens


def roofline_terms(rec):
    """Prefer trip-count-corrected totals (see repro.launch.hlo_cost);
    fall back to raw cost_analysis numbers for old records."""
    flops = rec.get("flops_corrected") or rec["flops"]
    nbytes = rec.get("bytes_corrected") or rec["bytes_accessed"]
    colls = rec.get("collective_bytes_corrected") or rec["collective_bytes"]
    comp = flops / PEAK_FLOPS_BF16
    mem = nbytes / HBM_BW
    coll = sum(colls.values()) / ICI_BW
    dom = max((comp, "compute"), (mem, "memory"), (coll, "collective"))
    # resource class: the two-way HBM-vs-FLOP split the scheduler's
    # interference model uses (collectives excluded — ICI, not HBM)
    rclass = resource_class_from_cost(flops, nbytes, RIDGE)
    return comp, mem, coll, dom[1], rclass


def main(csvout=None):
    csvout = csvout or Csv(("arch_x_shape", "terms_ms_c/m/coll",
                            "dominant|class|useful_ratio|fits_hbm"))
    if not os.path.exists(RESULTS):
        csvout.add("missing", 0, f"run dryrun --all --out {RESULTS} first")
        csvout.emit("Roofline (no dry-run results found)")
        return csvout
    with open(RESULTS) as f:
        recs = json.load(f)
    recs = [r for r in recs if r["mesh"] == "16x16"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        cfg = get_config(r["arch"])
        shape = SHAPES[r["shape"]]
        comp, mem, coll, dom, rclass = roofline_terms(r)
        mf = model_flops(cfg, shape)
        flops = r.get("flops_corrected") or r["flops"]
        useful = mf / max(flops * r["devices"], 1.0)
        peak = r["mem"]["peak_bytes"] / 2 ** 30
        csvout.add(
            f"{r['arch']} x {r['shape']}",
            f"{comp*1e3:.2f}/{mem*1e3:.2f}/{coll*1e3:.2f}",
            f"{dom}|{rclass}|{useful:.2f}|"
            f"{'Y' if peak <= 16 else f'N({peak:.0f}G)'}")
    csvout.emit("Roofline terms per (arch x shape), single-pod 16x16 "
                "(per-chip seconds basis)")
    return csvout


if __name__ == "__main__":
    main()
