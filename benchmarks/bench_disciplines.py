"""Intra-device queue disciplines (fifo / sjf / edf) on a Fig 16/17-shaped
serving mix: one interactive high-priority service sharing the device with
deadline-tagged low-priority batch services.

The mix mirrors the paper's cloud-serving combination (interactive hi
service with real host gaps; device-bound lo services whose kernels fit
those gaps) with the two ingredients the disciplines act on:

- lo services of two kernel sizes (short 1 ms / long 3.5 ms, both
  gap-fittable) — SJF clears the short streams first, which is where the
  mean lo-JCT win comes from;
- several instances per lo service, so instances TIE in predicted
  duration, with completion deadlines anti-correlated with park order
  (the urgent instance parks later) — FIFO tie-breaks serve the relaxed
  instance first and blow the tight deadline; EDF's deadline tie-break
  rescues it.

Reported per discipline: mean hi-JCT (QoS must hold — gap filling still
selects only lo work), mean lo-JCT, and the deadline-miss rate over the
tagged lo tasks. Acceptance gates (tracked in BENCH_disciplines.json):

    sjf_lo_jct_ok:  SJF mean lo-JCT <= FIFO mean lo-JCT
    edf_miss_ok:    EDF deadline misses <= FIFO deadline misses

Set BENCH_SMOKE=1 (CI) for a reduced instance count.

``main`` returns the Csv with a ``json_payload`` attribute —
``benchmarks.run`` persists it as BENCH_disciplines.json so the
discipline trade-off is tracked across PRs.
"""
from __future__ import annotations

import os
import statistics as st

from benchmarks.common import Csv
from repro.core.kernel_id import KernelID
from repro.core.queues import QUEUE_DISCIPLINES
from repro.core.scheduler import Mode, SimScheduler, profile_tasks
from repro.core.task import TaskKey, TaskSpec, TraceKernel

SMOKE = bool(int(os.environ.get("BENCH_SMOKE", "0")))

#: deadline slack (s) relative to arrival: tight on the LATER-parked
#: instance of each lo pair, loose on the earlier one, so FIFO park-order
#: tie-breaks work against the deadlines and EDF has something to fix
TIGHT_SHORT, LOOSE_SHORT = 0.12, 0.55
TIGHT_LONG, LOOSE_LONG = 0.18, 0.60


def discipline_mix(n_hi: int, n_short: int, n_long: int):
    """Interactive hi service (2 ms kernels, 5 ms host gaps, paced
    instances) + gap-fittable lo batch instances in two kernel sizes, each
    deadline-tagged."""
    tasks = []
    for i in range(n_hi):
        tasks.append(TaskSpec(
            TaskKey("hi"), 0,
            [TraceKernel(KernelID("hi/layer"), 0.002, 0.005)] * 12,
            arrival=0.09 * i))
    for i in range(n_short):
        arrival = 0.001 + 0.0002 * i
        slack = TIGHT_SHORT if i % 2 == 0 else LOOSE_SHORT
        tasks.append(TaskSpec(
            TaskKey("lo_short"), 5,
            [TraceKernel(KernelID("lo_short/layer"), 0.001, 0.0002)] * 18,
            arrival=arrival, deadline=arrival + slack))
    for i in range(n_long):
        arrival = 0.002 + 0.0002 * i
        slack = TIGHT_LONG if i % 2 == 1 else LOOSE_LONG
        tasks.append(TaskSpec(
            TaskKey("lo_long"), 5,
            [TraceKernel(KernelID("lo_long/layer"), 0.0035, 0.0002)] * 10,
            arrival=arrival, deadline=arrival + slack))
    return tasks


def main(csvout=None):
    csvout = csvout or Csv(("name", "value", "derived"))
    n_hi, n_short, n_long = (3, 2, 2) if SMOKE else (6, 4, 4)
    tasks = discipline_mix(n_hi, n_short, n_long)
    hi_idx = [i for i, t in enumerate(tasks) if t.priority == 0]
    lo_idx = [i for i, t in enumerate(tasks) if t.priority > 0]
    profiled = profile_tasks(tasks, T=3, jitter=0.0,
                             measurement_overhead=0.0)

    sweep = {}
    for disc in QUEUE_DISCIPLINES:
        rep = SimScheduler(tasks, Mode.FIKIT, profiled, jitter=0.03,
                           seed=0, queue_discipline=disc).run()
        sweep[disc] = {
            "hi_jct_ms": round(1e3 * st.mean(rep.jct(i) for i in hi_idx),
                               3),
            "lo_jct_ms": round(1e3 * st.mean(rep.jct(i) for i in lo_idx),
                               3),
            "deadline_misses": rep.deadline_misses,
            "deadlines_tagged": rep.deadlines_tagged,
            "deadline_miss_rate": round(rep.deadline_miss_rate, 3),
            "fills": rep.fills,
        }
        s = sweep[disc]
        csvout.add(f"{disc}", s["lo_jct_ms"],
                   f"hi JCT {s['hi_jct_ms']} ms, misses "
                   f"{s['deadline_misses']}/{s['deadlines_tagged']}, "
                   f"fills {s['fills']}")

    sjf_ok = sweep["sjf"]["lo_jct_ms"] <= sweep["fifo"]["lo_jct_ms"] + 1e-9
    edf_ok = (sweep["edf"]["deadline_misses"]
              <= sweep["fifo"]["deadline_misses"])
    csvout.add("sjf lo-JCT vs fifo",
               round(sweep["sjf"]["lo_jct_ms"]
                     / sweep["fifo"]["lo_jct_ms"], 3),
               "OK (<= 1.0 wanted)" if sjf_ok else "ABOVE FIFO")
    csvout.add("edf misses vs fifo",
               f"{sweep['edf']['deadline_misses']}"
               f"/{sweep['fifo']['deadline_misses']}",
               "OK" if edf_ok else "MORE MISSES THAN FIFO")
    csvout.emit("Queue disciplines on the Fig16/17 serving mix "
                "(lo JCT: sjf; deadline misses: edf; hi QoS: all)")
    csvout.json_payload = {
        "smoke": SMOKE,
        "n_hi": n_hi,
        "n_short": n_short,
        "n_long": n_long,
        "sweep": sweep,
        "sjf_lo_jct_ok": sjf_ok,
        "edf_miss_ok": edf_ok,
    }
    return csvout


if __name__ == "__main__":
    main()
