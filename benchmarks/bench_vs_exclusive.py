"""Paper Fig 18: low-priority JCT, FIKIT vs exclusive mode, as the ratio of
high:low task counts grows (1:1, 10:1, ..., 50:1).

Method follows the paper (§4.5.2): exclusive mode cannot co-run two
services, so the two services are executed sequentially in priority order
and the low-priority JCT is computed as (sum of high-priority solo JCTs +
its own solo JCT). FIKIT mode is simulated with the high service invoking
r tasks back-to-back while the low task scavenges inter-kernel gaps.

Paper claim: at 1:1 the modes are comparable; from 10:1 to 50:1 the
exclusive/FIKIT ratio rises LINEARLY while the FIKIT low JCT stays flat.
"""
from __future__ import annotations

from benchmarks.common import Csv, arch_trace, repeat_task
from repro.core.scheduler import Mode, SimScheduler, profile_tasks

RATIOS = [1, 10, 20, 30, 40, 50]


def main(csvout=None):
    csvout = csvout or Csv(("ratio", "exclusive_over_fikit_low_jct",
                            "fikit_low_jct_ms"))
    hi_proto = arch_trace("qwen3-4b", priority=0, interactive=True,
                          seq_tokens=48)
    # low kernels must fit the high task's ~4ms gaps — the regime where
    # FIKIT's gap filling keeps low-priority latency flat
    lo_proto = arch_trace("mamba2-2.7b", priority=5, interactive=False,
                          seq_tokens=64)
    profiled = profile_tasks([hi_proto, lo_proto], T=10, jitter=0.05)
    ratios_out = []
    for r in RATIOS:
        # FIKIT: high service continuously issues r tasks; low arrives at 0
        his = repeat_task(hi_proto, r, interval=hi_proto.solo_jct * 1.001)
        lo = repeat_task(lo_proto, 1, interval=0.0)[0]
        tasks = his + [lo]
        rep = SimScheduler(tasks, Mode.FIKIT, profiled, jitter=0.03).run()
        fikit_lo = rep.jct(len(tasks) - 1)
        # exclusive (paper's computation): low waits for ALL high tasks
        excl_lo = r * hi_proto.solo_jct + lo_proto.solo_jct
        ratio = excl_lo / fikit_lo
        ratios_out.append(ratio)
        csvout.add(f"{r}:1", round(ratio, 2), round(fikit_lo * 1e3, 2))
    xs, ys = RATIOS, ratios_out
    n = len(xs)
    mx, my = sum(xs) / n, sum(ys) / n
    cov = sum((x - mx) * (y - my) for x, y in zip(xs, ys))
    vx = sum((x - mx) ** 2 for x in xs)
    vy = sum((y - my) ** 2 for y in ys)
    corr = cov / (vx * vy) ** 0.5 if vx * vy else 0.0
    csvout.add("pearson_r_vs_ratio", round(corr, 3), "linear if ~1")
    csvout.emit("Fig18: Low-priority JCT speedup of FIKIT over exclusive "
                "mode vs task ratio")
    return csvout


if __name__ == "__main__":
    main()
