"""Interference-aware gap filling vs class-blind filling on an
adversarial memory-bound mix.

The workload is built so duration-only BestPrioFit makes the WRONG
choice: a memory-bound interactive hi service (2 ms kernels, 6 ms host
gaps) shares the device with two low-priority filler pools —

- ``lo_mem``: memory-bound 4.5 ms kernels. Longest fit under the 6 ms
  gap, so the class-blind policy always picks them; co-running against
  the memory-bound holder they physically slow down by the ground-truth
  (mem, mem) factor 1.6x -> 7.2 ms of true occupancy, overshooting every
  gap by ~1.2 ms and delaying the hi service.
- ``lo_cpu``: compute-bound 4.0 ms kernels. Slightly shorter, but
  near-free to co-run against a memory-bound holder (1.05x -> 4.2 ms,
  fits).

Three runs over the same ground-truth physical environment
(``interference_env``, keyed by TraceKernel.kclass):

    off      class-blind BestPrioFit (interference=None)
    aware    interference-aware fit with the true-ish coefficient table
    learned  coefficients start flat at 1.0 and are refined live by the
             online measurement loop (observed/predicted ratios folded
             at epoch commits) — the (mem, mem) coefficient must climb
             past the exclusion threshold on its own

Gates (tracked in BENCH_interference.json, enforced by
``scripts/check_bench_gates.py``): aware hi-JCT improves vs off
(``hi_jct_ratio_vs_off``), fill throughput stays in a band
(``fill_ratio_vs_off``), and the learned (mem, mem) coefficient rises
above ``min_learned_mm_coeff``.

Set BENCH_SMOKE=1 (CI) for reduced kernel counts.
"""
from __future__ import annotations

import os
import statistics as st

from benchmarks.common import Csv
from repro.core.interference import (COMPUTE_BOUND, MEMORY_BOUND,
                                     InterferenceModel)
from repro.core.kernel_id import KernelID
from repro.core.online import OnlineConfig
from repro.core.profiler import ProfiledData
from repro.core.scheduler import Mode, SimScheduler, profile_tasks
from repro.core.task import TaskKey, TaskSpec, TraceKernel

SMOKE = bool(int(os.environ.get("BENCH_SMOKE", "0")))

#: ground-truth physical slowdown per (holder class, filler class) —
#: what the simulated device actually does to co-running fillers
TRUE_ENV = {
    (MEMORY_BOUND, MEMORY_BOUND): 1.6,
    (COMPUTE_BOUND, COMPUTE_BOUND): 1.15,
    (COMPUTE_BOUND, MEMORY_BOUND): 1.25,
    (MEMORY_BOUND, COMPUTE_BOUND): 1.05,
}


def interference_mix(n_hi_kernels: int, n_lo_kernels: int):
    """Memory-bound interactive hi stream + two adversarial filler pools
    (memory-bound longest-fit bait vs compute-bound near-free)."""
    tasks = [TaskSpec(
        TaskKey("hi"), 0,
        [TraceKernel(KernelID("hi/layer"), 0.002, 0.006,
                     kclass=MEMORY_BOUND)] * n_hi_kernels,
        arrival=0.0)]
    for i in range(2):
        tasks.append(TaskSpec(
            TaskKey("lo_mem"), 8,
            [TraceKernel(KernelID("lo_mem/layer"), 0.0045, 0.0002,
                         kclass=MEMORY_BOUND)] * n_lo_kernels,
            arrival=0.001 + 0.0002 * i, max_inflight=16))
    for i in range(2):
        tasks.append(TaskSpec(
            TaskKey("lo_cpu"), 8,
            [TraceKernel(KernelID("lo_cpu/layer"), 0.004, 0.0002,
                         kclass=COMPUTE_BOUND)] * n_lo_kernels,
            arrival=0.002 + 0.0002 * i, max_inflight=16))
    return tasks


def _fresh(profiled):
    """Per-run copy of the profile store (online runs mutate it)."""
    data = ProfiledData()
    for key in profiled.keys():
        data.load(profiled.get(key).clone())
    return data


def _run(tasks, profiled, hi_idx, *, interference=None, online=None):
    rep = SimScheduler(tasks, Mode.FIKIT, _fresh(profiled), jitter=0.0,
                       seed=0, interference=interference,
                       interference_env=TRUE_ENV, online=online).run()
    hi_jct = st.mean(rep.jct(i) for i in hi_idx)
    return rep, hi_jct


def main(csvout=None):
    csvout = csvout or Csv(("name", "value", "derived"))
    n_hi, n_lo = (60, 100) if SMOKE else (300, 400)
    tasks = interference_mix(n_hi, n_lo)
    hi_idx = [i for i, t in enumerate(tasks) if t.priority == 0]
    profiled = profile_tasks(tasks, T=3, jitter=0.0,
                             measurement_overhead=0.0)

    rep_off, jct_off = _run(tasks, profiled, hi_idx)
    rep_aware, jct_aware = _run(
        tasks, profiled, hi_idx,
        interference=InterferenceModel(TRUE_ENV))
    learned_model = InterferenceModel({p: 1.0 for p in TRUE_ENV})
    rep_learn, jct_learn = _run(
        tasks, profiled, hi_idx, interference=learned_model,
        online=OnlineConfig(epoch_observations=16, ema_alpha=0.5))
    mm = learned_model.coeff(MEMORY_BOUND, MEMORY_BOUND)

    ratio = jct_aware / jct_off
    learn_ratio = jct_learn / jct_off
    fill_ratio = rep_aware.fills / max(rep_off.fills, 1)
    csvout.add("hi JCT off", round(1e3 * jct_off, 3),
               f"fills {rep_off.fills}, "
               f"overshoot {1e3 * rep_off.overshoot_time:.1f} ms")
    csvout.add("hi JCT aware", round(1e3 * jct_aware, 3),
               f"fills {rep_aware.fills}, "
               f"overshoot {1e3 * rep_aware.overshoot_time:.1f} ms, "
               f"ratio vs off {ratio:.3f}")
    csvout.add("hi JCT learned", round(1e3 * jct_learn, 3),
               f"fills {rep_learn.fills}, ratio vs off "
               f"{learn_ratio:.3f}, mm coeff {mm:.3f}")
    csvout.emit("Interference-aware gap filling vs class-blind "
                "(memory-bound adversarial fillers)")
    csvout.json_payload = {
        "smoke": SMOKE,
        "hi_jct_off_ms": round(1e3 * jct_off, 4),
        "hi_jct_aware_ms": round(1e3 * jct_aware, 4),
        "hi_jct_learned_ms": round(1e3 * jct_learn, 4),
        "hi_jct_ratio_vs_off": round(ratio, 4),
        "hi_jct_learned_ratio_vs_off": round(learn_ratio, 4),
        "fills_off": rep_off.fills,
        "fills_aware": rep_aware.fills,
        "fills_learned": rep_learn.fills,
        "fill_ratio_vs_off": round(fill_ratio, 4),
        "learned_mm_coeff": round(mm, 4),
        "overshoot_off_ms": round(1e3 * rep_off.overshoot_time, 3),
        "overshoot_aware_ms": round(1e3 * rep_aware.overshoot_time, 3),
    }
    return csvout


if __name__ == "__main__":
    main()
