"""Multi-device placement benchmark: one priority workload mix spread over
K devices through the ``PlacementLayer``.

Sweeps K in {1, 2, 4, 8} over a fixed cluster mix (interactive
high-priority services + device-bound batch services, staggered arrivals)
under FIKIT scheduling with least-loaded placement + work stealing, and
reports per K:

- aggregate throughput (tasks/s) and its scaling vs K=1 — the placement
  layer's reason to exist; the K=2 point is the acceptance gate (>= 1.7x);
- mean high-priority and low-priority JCT — hi JCT must be NO WORSE than
  single-device FIKIT (per-device isolation is not compromised by the
  sharing layer);
- per-device utilization and steal count.

Set BENCH_SMOKE=1 (CI) for a tiny workload and K in {1, 2} only.

``main`` returns the Csv with a ``json_payload`` attribute —
``benchmarks.run`` persists it as BENCH_placement.json so placement
scaling is tracked across PRs.
"""
from __future__ import annotations

import os
import statistics as stats

from benchmarks.common import Csv
from repro.core.kernel_id import KernelID
from repro.core.scheduler import Mode, SimScheduler, profile_tasks
from repro.core.task import TaskKey, TaskSpec, TraceKernel

SMOKE = bool(int(os.environ.get("BENCH_SMOKE", "0")))
DEVICE_COUNTS = (1, 2) if SMOKE else (1, 2, 4, 8)


def cluster_mix(n_hi: int, n_lo: int):
    """Interactive hi-priority services (sync clients, real host gaps) +
    device-bound lo-priority batch services (async, negligible gaps),
    arrivals staggered so the cluster sees a continuous mixed load."""
    tasks = []
    for i in range(n_hi):
        kid = KernelID(f"hi{i}/layer")
        kernels = [TraceKernel(kid, 0.002, 0.003)] * 14
        tasks.append(TaskSpec(TaskKey(f"hi{i}"), 0, kernels,
                              arrival=0.0008 * i))
    for i in range(n_lo):
        kid = KernelID(f"lo{i}/layer")
        # 2.5 ms kernels fit strictly inside the hi services' 3 ms gaps, so
        # co-located batch work is gap-fillable (the FIKIT win) while still
        # being device-bound enough to need extra devices for throughput
        kernels = [TraceKernel(kid, 0.0025, 0.0002)] * 22
        tasks.append(TaskSpec(TaskKey(f"lo{i}"), 5 + i % 5, kernels,
                              arrival=0.0005 + 0.0011 * i,
                              max_inflight=8))
    return tasks


def main(csvout=None):
    csvout = csvout or Csv(header=("name", "value", "derived"))
    n_hi, n_lo = (3, 6) if SMOKE else (8, 16)
    tasks = cluster_mix(n_hi, n_lo)
    hi_idx = [i for i, t in enumerate(tasks) if t.priority == 0]
    lo_idx = [i for i, t in enumerate(tasks) if t.priority > 0]
    profiled = profile_tasks(tasks, T=3, jitter=0.0,
                             measurement_overhead=0.0)

    sweep = {}
    for K in DEVICE_COUNTS:
        rep = SimScheduler(tasks, Mode.FIKIT, profiled, jitter=0.0,
                           devices=K, discipline="least_loaded",
                           steal=True).run()
        ms = rep.makespan
        sweep[K] = {
            "makespan_ms": round(1e3 * ms, 3),
            "throughput_tasks_per_s": round(len(tasks) / ms, 1),
            "hi_jct_ms": round(1e3 * stats.mean(rep.jct(i)
                                                for i in hi_idx), 3),
            "lo_jct_ms": round(1e3 * stats.mean(rep.jct(i)
                                                for i in lo_idx), 3),
            "per_device_utilization": [round(u, 3) for u in
                                       rep.per_device_utilization()],
            "fills": rep.fills,
            "steals": rep.steals,
        }
        csvout.add(f"K={K} makespan", sweep[K]["makespan_ms"],
                   f"{sweep[K]['throughput_tasks_per_s']} tasks/s, "
                   f"hi JCT {sweep[K]['hi_jct_ms']} ms, "
                   f"steals {rep.steals}")

    base = sweep[DEVICE_COUNTS[0]]
    scaling = {K: round(base["makespan_ms"] / sweep[K]["makespan_ms"], 3)
               for K in DEVICE_COUNTS}
    hi_ratio = {K: round(sweep[K]["hi_jct_ms"] / base["hi_jct_ms"], 3)
                for K in DEVICE_COUNTS}
    for K in DEVICE_COUNTS[1:]:
        ok = scaling[K] >= 1.7 if K == 2 else scaling[K] > scaling[K // 2]
        csvout.add(f"K={K} throughput scaling", scaling[K],
                   ("OK" if ok else "BELOW TARGET") +
                   f", hi JCT ratio {hi_ratio[K]} (<= 1.0 wanted)")
    csvout.emit("Multi-device placement: throughput scaling + hi-priority "
                "JCT protection (least_loaded + steal)")
    csvout.json_payload = {
        "smoke": SMOKE,
        "n_hi": n_hi,
        "n_lo": n_lo,
        "device_counts": list(DEVICE_COUNTS),
        "sweep": sweep,
        "throughput_scaling_vs_k1": scaling,
        "hi_jct_ratio_vs_k1": hi_ratio,
        "k2_scaling_ok": scaling.get(2, 0.0) >= 1.7,
        "k2_hi_jct_ok": hi_ratio.get(2, 9.9) <= 1.0 + 1e-9,
    }
    return csvout


if __name__ == "__main__":
    main()
