"""Paper Fig 21 + Table 3: low-priority JCT stability. High-priority service
runs continuously; low-priority tasks inserted periodically; report the
coefficient of variation of the low-priority JCTs under FIKIT sharing.

Paper claim: CV in 0.095-0.164 across the 10 pairings (<< 1: stable and
predictable).
"""
from __future__ import annotations

import statistics as st

from benchmarks.common import (PAIRS, Csv, arch_trace,
                               continuous_stream, repeat_task)
from repro.core.scheduler import Mode, SimScheduler, profile_tasks

N_LOW = 40
INTERVAL = 0.5


def _fit_seq(low: str, gap: float) -> int:
    """Largest batch whose per-layer kernel fits comfortably in the high
    task's gap — the paper's 'what tasks are suitable for sharing' knob
    (§5): low-priority kernels must fit the gaps to scavenge them."""
    from benchmarks.common import TIME_SCALE, _layer_cost
    from repro.config import get_config
    cfg = get_config(low)
    cost = max(_layer_cost(cfg), cfg.vocab_size * cfg.d_model) * TIME_SCALE
    for seq in (128, 64, 32, 16, 8):
        if cost * seq <= 0.6 * gap:
            return seq
    return 8


def run_pair(high: str, low: str, seed: int = 0):
    hi_proto = arch_trace(high, priority=0, interactive=True, seq_tokens=48)
    lo_proto = arch_trace(low, priority=5, interactive=False,
                          seq_tokens=_fit_seq(low, 0.004))
    profiled = profile_tasks([hi_proto, lo_proto], T=10, jitter=0.05,
                             seed=seed)
    horizon = N_LOW * INTERVAL
    n_hi = max(3, int(horizon / max(hi_proto.solo_jct, 1e-9)) + 2)
    # 'high-priority service runs continuously': one long kernel stream
    hi_stream = continuous_stream(hi_proto, n_hi)
    lo_tasks = repeat_task(lo_proto, N_LOW, interval=INTERVAL, start=0.02)
    tasks = [hi_stream] + lo_tasks
    rep = SimScheduler(tasks, Mode.FIKIT, profiled, jitter=0.05,
                       seed=seed).run()
    lo_j = [rep.jct(1 + i) for i in range(N_LOW)]
    mu = st.mean(lo_j)
    sigma = st.pstdev(lo_j)
    return sigma, mu, sigma / mu


def main(csvout=None):
    csvout = csvout or Csv(("pair", "low_jct_cv", "mu_ms"))
    cvs = []
    for label, high, low in PAIRS:
        sigma, mu, cv = run_pair(high, low)
        cvs.append(cv)
        csvout.add(f"{label} H:{high} L:{low}", round(cv, 4),
                   round(mu * 1e3, 2))
    csvout.add("max_cv", round(max(cvs), 4), "stable if << 1")
    csvout.emit("Fig21/Table3: Low-priority JCT stability under FIKIT "
                "(coefficient of variation)")
    return csvout


if __name__ == "__main__":
    main()
