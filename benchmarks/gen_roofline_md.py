"""Regenerate the §Roofline table in EXPERIMENTS.md from
dryrun_results.json (single-pod baseline + multipod presence column)."""
from __future__ import annotations

import json
import sys

from benchmarks.bench_roofline import model_flops, roofline_terms
from repro.config import SHAPES, get_config

MARK_A = "## §Roofline — per (arch × shape), single-pod 16×16 (deliverable g)"
MARK_B = "## §Perf"


def table() -> str:
    with open("dryrun_results.json") as f:
        recs = json.load(f)
    single = {(r["arch"], r["shape"]): r for r in recs
              if r["mesh"] == "16x16"}
    multi = {(r["arch"], r["shape"]) for r in recs if r["mesh"] == "2x16x16"}
    rows = ["| arch × shape | compute ms | memory ms | collective ms | "
            "dominant | useful | fits 16G | 512-chip |",
            "|---|---|---|---|---|---|---|---|"]
    for key in sorted(single):
        r = single[key]
        cfg = get_config(r["arch"])
        shape = SHAPES[r["shape"]]
        comp, mem, coll, dom = roofline_terms(r)
        flops = r.get("flops_corrected") or r["flops"]
        useful = model_flops(cfg, shape) / max(flops * r["devices"], 1.0)
        peak = r["mem"]["peak_bytes"] / 2 ** 30
        fits = "✓" if peak <= 16 else f"✗ {peak:.0f}G"
        mp = "✓" if key in multi else "—"
        rows.append(
            f"| {r['arch']} × {r['shape']} | {comp*1e3:.1f} | {mem*1e3:.0f} "
            f"| {coll*1e3:.0f} | {dom} | {useful:.2f} | {fits} | {mp} |")
    notes = """
Terms are per-chip seconds ×1e3 from the trip-count-corrected compiled
artifact (`repro.launch.hlo_cost`): compute = dot-FLOPs / 197 TF; memory =
top-level-op IO bytes / 819 GB/s; collective = collective traffic /
50 GB/s. ``useful`` = MODEL_FLOPS (6·N·D train, 2·N·D prefill, 2·N_active·B
decode) / (corrected FLOPs × 256 chips) — catches remat/capacity/dispatch
waste. Outliers >1 (seamless train, recurrentgemma) are architectures whose
useful work is not dot-shaped (encoder counted at decoder rate; elementwise
RG-LRU recurrence) — noted, not errors. The memory term dominating most
training rows reflects the fp32 intermediates this CPU-lowered artifact
keeps; the per-combo one-liner "what would move the dominant term down" is
the §Perf backlog list below.

Per-combo "what would move the dominant term down":
- train rows (memory-dominated): keep residuals/softmax in bf16
  (≈2× bytes), larger microbatches once HBM allows, fused attention kernel
  (flash_attention Pallas path) instead of the jnp reference path.
- deepseek/llama4 train+prefill (✗ fits): H1 levers (bf16 moments,
  ZeRO-over-pod) + capacity-factor 1.0 dispatch.
- decode rows (collective-dominated before H2): fixed by
  `DECODE_PREFER_SEQ_SHARD` (see §Perf H2) — baseline rows kept here.
- recurrentgemma rows (collective): H3 gate-gather (see §Perf H3).
- long_500k rows: already sub-ms; bound by per-step latency floors, not
  throughput terms.
"""
    return "\n".join(rows) + "\n" + notes


def main():
    with open("EXPERIMENTS.md") as f:
        txt = f.read()
    a = txt.index(MARK_A)
    b = txt.index(MARK_B)
    new = txt[:a] + MARK_A + "\n\n" + table() + "\n" + txt[b:]
    with open("EXPERIMENTS.md", "w") as f:
        f.write(new)
    print("EXPERIMENTS.md §Roofline regenerated")


if __name__ == "__main__":
    sys.exit(main())
