"""Benchmark harness entry point: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--skip-wallclock]

Prints ``name,value,derived`` CSV blocks per benchmark. A benchmark whose
``main()`` returns a Csv carrying a ``json_payload`` attribute also gets a
machine-readable ``BENCH_<name>.json`` written next to the repo root, so
perf trajectories (e.g. scheduler decision latency by queue depth) are
tracked across PRs instead of living only in scrollback.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

BENCHES = [
    ("scheduler_micro", "benchmarks.bench_scheduler_micro"),
    ("placement", "benchmarks.bench_placement"),              # multi-device
    ("disciplines", "benchmarks.bench_disciplines"),          # sjf/edf
    ("interference", "benchmarks.bench_interference"),        # class-aware
    ("recovery", "benchmarks.bench_recovery"),                # ops plane
    ("sharing_jct", "benchmarks.bench_sharing_jct"),          # Fig 16/17
    ("vs_exclusive", "benchmarks.bench_vs_exclusive"),        # Fig 18
    ("preemption", "benchmarks.bench_preemption"),            # Fig 19/20
    ("stability", "benchmarks.bench_stability"),              # Fig 21/T3
    ("roofline", "benchmarks.bench_roofline"),                # deliverable g
    ("serving_load", "benchmarks.bench_serving_load"),        # admission
    ("fleet", "benchmarks.bench_fleet"),                      # cluster scale
    ("workers", "benchmarks.bench_workers"),                  # worker fleet
    ("overheads", "benchmarks.bench_overheads"),              # Fig 13/14/15
]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--skip-wallclock", action="store_true",
                    help="skip the slow real-execution overhead benchmarks")
    args = ap.parse_args(argv)
    t0 = time.time()
    failures = []
    for name, module in BENCHES:
        if args.only and args.only != name:
            continue
        if args.skip_wallclock and name == "overheads":
            continue
        print(f"=== {name} ===")
        t = time.time()
        try:
            mod = __import__(module, fromlist=["main"])
            ret = mod.main()
            payload = getattr(ret, "json_payload", None)
            if payload is not None:
                out = os.path.join(os.path.dirname(__file__), os.pardir,
                                   f"BENCH_{name}.json")
                out = os.path.normpath(out)
                with open(out, "w") as f:
                    json.dump(payload, f, indent=2, sort_keys=True)
                print(f"wrote {out}")
        except Exception as e:  # noqa: BLE001
            failures.append((name, repr(e)))
            print(f"BENCH FAIL {name}: {e}")
        print(f"({name}: {time.time()-t:.1f}s)\n")
    print(f"total: {time.time()-t0:.1f}s")
    if failures:
        print("FAILURES:", failures)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
