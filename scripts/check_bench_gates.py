"""CI bench-regression gates: compare the freshly-written BENCH_<name>.json
payloads against the committed tolerances in benchmarks/bench_gates.json
and exit non-zero on any regression.

    python -m scripts.check_bench_gates
    python -m scripts.check_bench_gates --require scheduler_micro,placement
    python -m scripts.check_bench_gates --require all

Before this gate existed CI only UPLOADED the bench JSONs — a scheduling
fast-path regression (super-linear decision latency, a discipline path
drifting past 2x FIFO, placement scaling collapse, the Fig-14 overhead
band) would merge silently and only surface when someone eyeballed an
artifact. Now the smoke benches run AND gate on every PR; the nightly
workflow additionally gates the full (non-smoke) suite including the
wall-clock Fig-14 overheads with the online measurement loop.

Gate semantics per benchmark (tolerances in benchmarks/bench_gates.json):

- scheduler_micro — indexed decision latency must grow sub-linearly in
  queue depth, every per-decision latency stays under an absolute
  ceiling, and the sjf/edf discipline paths stay within the FIFO
  multiplier.
- placement — K=2 throughput scaling >= the floor (the placement layer's
  reason to exist) and K=2 hi-priority JCT ratio <= the ceiling
  (per-device QoS not compromised).
- disciplines — the sjf lo-JCT and edf deadline-miss wins hold, and
  neither discipline inflates hi-priority JCT past the FIFO ratio
  ceiling.
- interference — interference-aware gap filling improves hi-priority JCT
  vs the class-blind policy under memory-bound adversarial fillers
  (ratio <= ceiling < 1), fill throughput stays inside a band (the
  aware policy must keep filling, not give up), and the online-learned
  (memory, memory) coefficient climbs past its floor from a flat-1.0
  start.
- recovery — ops-plane durability stays cheap: worst per-job crash
  recovery latency under an absolute ceiling, per-job recovery cost
  roughly flat as the store grows (no super-linear reload), and a
  cancel storm against low-priority tasks disturbs the high-priority
  JCT by at most the ratio ceiling.
- serving_load — the admission plane holds its QoS contract under 2x
  open-loop overload: gold p99 stays within a bounded multiple of its
  underload baseline, gold goodput (in-SLO completions / offered) stays
  above the floor, no request is shed or admitted while a higher class
  has queued work (priority_inversions == 0), per-class conservation
  holds (offered == admitted + rejected + shed + requeued), and the
  wired-but-disabled plane's policy decision trace is bit-identical to
  the no-plane direct invoke path.
- workers — the multi-process worker plane actually buys throughput:
  aggregate goodput scales >= the floor from 1 to 2 workers draining
  one store, the gold class's p99 completion latency does not regress
  past its ratio ceiling across the fan-out (strict-priority claims),
  and a healthy fleet reclaims zero leases.
- overheads (nightly; wall clock) — the online measurement loop's
  marginal cost over the offline FIKIT sharing stage (median across
  archs of on-vs-off JCT delta) stays inside the paper's Fig-14 +/-5%
  band. The engine-vs-direct-base percentages are reported in the
  payload for paper comparability but not gated: on CPU runners they
  carry large per-arch systematic effects in both directions that are
  identical with the loop on or off.

A benchmark in the required set whose BENCH json is missing FAILS (the
bench crashed or was skipped); a non-required missing benchmark is
reported and skipped.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Tuple

REPO = Path(__file__).resolve().parent.parent
TOLERANCES = REPO / "benchmarks" / "bench_gates.json"

#: the smoke benches every PR runs; "overheads" joins in the nightly run
DEFAULT_REQUIRED = ("scheduler_micro", "placement", "disciplines",
                    "interference", "recovery", "serving_load", "fleet",
                    "workers")
ALL_GATED = DEFAULT_REQUIRED + ("overheads",)

Check = Tuple[str, bool, str]          # (gate name, ok, detail)


def _check_scheduler_micro(p: dict, tol: dict) -> List[Check]:
    sweep = p["best_prio_fit_sweep"]
    disc = p["queue_discipline_sweep"]
    max_us = max(sweep["indexed_us"].values())
    return [
        ("sublinear decision latency", bool(sweep["sublinear"])
         or not tol["require_sublinear"],
         f"growth {sweep['latency_growth_64_to_max']}x over "
         f"{sweep['depth_ratio']:g}x depth"),
        ("per-decision latency ceiling",
         max_us <= tol["max_indexed_decision_us"],
         f"max {max_us}us <= {tol['max_indexed_decision_us']}us"),
        ("discipline overhead vs fifo",
         disc["max_overhead_vs_fifo"]
         <= tol["max_discipline_overhead_vs_fifo"],
         f"{disc['max_overhead_vs_fifo']}x <= "
         f"{tol['max_discipline_overhead_vs_fifo']}x"),
    ]


def _check_placement(p: dict, tol: dict) -> List[Check]:
    # json object keys are strings; device counts arrive as "2"
    scale = p["throughput_scaling_vs_k1"].get("2")
    hi = p["hi_jct_ratio_vs_k1"].get("2")
    checks: List[Check] = []
    if scale is None:
        return [("K=2 present", False, "no K=2 sweep in payload")]
    checks.append(("K=2 throughput scaling",
                   scale >= tol["min_k2_throughput_scaling"],
                   f"{scale}x >= {tol['min_k2_throughput_scaling']}x"))
    checks.append(("K=2 hi-JCT ratio",
                   hi <= tol["max_k2_hi_jct_ratio"],
                   f"{hi} <= {tol['max_k2_hi_jct_ratio']}"))
    return checks


def _check_disciplines(p: dict, tol: dict) -> List[Check]:
    checks: List[Check] = [
        ("sjf lo-JCT <= fifo", bool(p["sjf_lo_jct_ok"])
         or not tol["require_sjf_lo_jct_ok"], "sjf_lo_jct_ok"),
        ("edf misses <= fifo", bool(p["edf_miss_ok"])
         or not tol["require_edf_miss_ok"], "edf_miss_ok"),
    ]
    fifo_hi = p["sweep"]["fifo"]["hi_jct_ms"]
    for d, row in sorted(p["sweep"].items()):
        if d == "fifo":
            continue
        ratio = row["hi_jct_ms"] / fifo_hi
        checks.append((f"{d} hi-JCT ratio vs fifo",
                       ratio <= tol["max_hi_jct_ratio_vs_fifo"],
                       f"{ratio:.3f} <= {tol['max_hi_jct_ratio_vs_fifo']}"))
    return checks


def _check_interference(p: dict, tol: dict) -> List[Check]:
    ratio = p["hi_jct_ratio_vs_off"]
    fills = p["fill_ratio_vs_off"]
    mm = p["learned_mm_coeff"]
    return [
        ("aware hi-JCT improves vs class-blind",
         ratio <= tol["max_hi_jct_ratio_vs_off"],
         f"{ratio} <= {tol['max_hi_jct_ratio_vs_off']}"),
        ("fill throughput in band",
         tol["min_fill_ratio_vs_off"] <= fills
         <= tol["max_fill_ratio_vs_off"],
         f"{tol['min_fill_ratio_vs_off']} <= {fills} <= "
         f"{tol['max_fill_ratio_vs_off']}"),
        ("learned (mem,mem) coefficient",
         mm >= tol["min_learned_mm_coeff"],
         f"{mm} >= {tol['min_learned_mm_coeff']}"),
    ]


def _check_overheads(p: dict, tol: dict) -> List[Check]:
    med = p["fig14_online_delta_med_pct"]
    return [
        ("fig14 online-loop cost vs fikit (median across archs)",
         abs(med) < tol["max_fig14_online_delta_pct"],
         f"|{med}%| < {tol['max_fig14_online_delta_pct']}% "
         f"(max-abs arch {p['fig14_online_delta_max_abs_pct']}%)"),
    ]


def _check_recovery(p: dict, tol: dict) -> List[Check]:
    sweep = p["recovery_sweep"]
    worst = max(sweep["per_job_us"].values())
    growth = sweep["growth_vs_smallest"]
    storm = p["cancel_storm"]["hi_jct_ratio_vs_no_storm"]
    return [
        ("per-job recovery latency ceiling",
         worst <= tol["max_recovery_us_per_job"],
         f"worst {worst}us/job <= {tol['max_recovery_us_per_job']}us"),
        ("recovery cost flat in store size",
         growth <= tol["max_recovery_growth"],
         f"per-job growth {growth}x <= {tol['max_recovery_growth']}x over "
         f"{sweep['size_ratio']:g}x stored jobs"),
        ("hi-JCT disturbance under lo cancel storm",
         storm <= tol["max_cancel_storm_hi_jct_ratio"],
         f"{storm} <= {tol['max_cancel_storm_hi_jct_ratio']}"),
    ]


def _check_serving_load(p: dict, tol: dict) -> List[Check]:
    ratio = p["hi_p99_overload_ratio"]
    goodput = p["hi_goodput_overload"]
    return [
        ("hi-class p99 bounded under overload",
         ratio <= tol["max_hi_p99_overload_ratio"],
         f"{ratio:.2f}x <= {tol['max_hi_p99_overload_ratio']}x "
         f"(gold p99 overload vs underload)"),
        ("hi-class goodput floor under overload",
         goodput >= tol["min_hi_goodput"],
         f"{goodput} >= {tol['min_hi_goodput']}"),
        ("shed ordering invariant",
         bool(p["shed_ordering_ok"]) or not tol["require_shed_ordering"],
         f"priority_inversions "
         f"{p['overload']['priority_inversions']}, every admit saw "
         f"empty higher queues"),
        ("per-class conservation",
         bool(p["conservation_ok"]) or not tol["require_conservation"],
         "offered == admitted + rejected + shed + requeued"),
        ("admission OFF bit-identical to direct invoke",
         bool(p["admission_off_trace_identical"])
         or not tol["require_admission_off_trace_identical"],
         "normalized policy decision traces equal"),
    ]


def _check_fleet(p: dict, tol: dict) -> List[Check]:
    scale = p["scale"]
    eps = scale["events_per_sec"]
    budget = (tol["max_wall_s_smoke"] if p.get("smoke")
              else tol["max_wall_s_full"])
    ratio = p["protection"]["hi_p99_protect_ratio"]
    return [
        ("events/sec floor", eps >= tol["min_events_per_sec"],
         f"{eps:.0f} >= {tol['min_events_per_sec']} "
         f"({scale['events']} events over {p['devices']} devices)"),
        ("scale wall-clock budget", scale["wall_s"] <= budget,
         f"{scale['wall_s']:.1f}s <= {budget:g}s "
         f"({'smoke' if p.get('smoke') else 'full nightly'} scenario)"),
        ("fast core bit-identical to reference core",
         bool(p["fast_vs_reference"]["trace_identical"])
         or not tol["require_fast_ref_trace_identical"],
         f"speedup {p['fast_vs_reference']['speedup']:.2f}x"),
        ("sharded fleet bit-identical to monolithic",
         bool(p["fleet_mono_trace_identical"])
         or not tol["require_fleet_mono_trace_identical"],
         "remapped per-device decision traces equal"),
        ("hi-priority p99 protection at fleet scale",
         ratio <= tol["max_hi_p99_protect_ratio"],
         f"FIKIT/SHARING hi p99 {ratio:.3f} <= "
         f"{tol['max_hi_p99_protect_ratio']} at "
         f"{p['protection']['util_per_device']}x load"),
        ("deadline-miss priority ordering",
         bool(p["miss_ordering_ok"]) or not tol["require_miss_ordering"],
         "hi-class miss rate <= lo-class at every load point"),
    ]


def _check_workers(p: dict, tol: dict) -> List[Check]:
    s = p["scaling"]
    return [
        ("aggregate goodput scaling 1 -> 2 workers",
         s["goodput_scaling_2w_vs_1w"] >= tol["min_goodput_scaling_2w"],
         f"{s['goodput_scaling_2w_vs_1w']}x >= "
         f"{tol['min_goodput_scaling_2w']}x "
         f"({p['fleets']['1']['goodput_kps']} -> "
         f"{p['fleets']['2']['goodput_kps']} kernels/s)"),
        ("gold p99 protection across the fan-out",
         s["gold_p99_ratio_2w_vs_1w"]
         <= tol["max_gold_p99_ratio_2w_vs_1w"],
         f"gold p99 2w/1w {s['gold_p99_ratio_2w_vs_1w']} <= "
         f"{tol['max_gold_p99_ratio_2w_vs_1w']}"),
        ("zero lease churn in a healthy fleet",
         s["lease_churn_total"] <= tol["max_lease_churn"],
         f"{s['lease_churn_total']} reclaims <= "
         f"{tol['max_lease_churn']}"),
    ]


CHECKERS = {
    "scheduler_micro": _check_scheduler_micro,
    "placement": _check_placement,
    "disciplines": _check_disciplines,
    "interference": _check_interference,
    "overheads": _check_overheads,
    "recovery": _check_recovery,
    "serving_load": _check_serving_load,
    "fleet": _check_fleet,
    "workers": _check_workers,
}


def run_gates(required, repo: Path = None,
              tolerances_path: Path = None) -> int:
    """Evaluate every gate; ``repo``/``tolerances_path`` override the
    module defaults so the unit tests can point at synthetic payloads."""
    repo = REPO if repo is None else Path(repo)
    tolerances_path = (TOLERANCES if tolerances_path is None
                       else Path(tolerances_path))
    tolerances = json.loads(tolerances_path.read_text())
    failures = 0
    for name in ALL_GATED:
        path = repo / f"BENCH_{name}.json"
        if not path.exists():
            if name in required:
                print(f"FAIL {name}: required but {path.name} missing — "
                      f"the bench crashed or never ran; re-run it with "
                      f"`python -m benchmarks.run --only {name}`")
                failures += 1
            else:
                print(f"skip {name}: {path.name} not present")
            continue
        try:
            payload = json.loads(path.read_text())
        except json.JSONDecodeError as e:
            print(f"FAIL {name}: {path.name} is not valid JSON "
                  f"(line {e.lineno}: {e.msg}) — the bench was likely "
                  f"interrupted mid-write; re-run it with "
                  f"`python -m benchmarks.run --only {name}`")
            failures += 1
            continue
        smoke = " (smoke)" if isinstance(payload, dict) \
            and payload.get("smoke") else ""
        try:
            checks = CHECKERS[name](payload, tolerances[name])
        except (KeyError, TypeError, AttributeError,
                ZeroDivisionError) as e:
            print(f"FAIL {name}{smoke}: {path.name} is malformed — "
                  f"missing or mistyped field ({e!r}); re-run the bench "
                  f"with `python -m benchmarks.run --only {name}`")
            failures += 1
            continue
        for gate, ok, detail in checks:
            status = "ok  " if ok else "FAIL"
            print(f"{status} {name}{smoke}: {gate} — {detail}")
            failures += 0 if ok else 1
    if failures:
        try:
            tol_name = tolerances_path.relative_to(repo)
        except ValueError:
            tol_name = tolerances_path
        print(f"\n{failures} bench gate(s) failed against {tol_name}")
        return 1
    print("\nall bench gates passed")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--require", default=",".join(DEFAULT_REQUIRED),
                    help="comma-separated benchmarks whose json MUST be "
                         "present ('all' = every gated benchmark); "
                         "default: the PR smoke set")
    args = ap.parse_args(argv)
    required = set(ALL_GATED) if args.require == "all" else {
        r for r in args.require.split(",") if r}
    unknown = required - set(ALL_GATED)
    if unknown:
        print(f"unknown benchmark(s) in --require: {sorted(unknown)} "
              f"(gated: {list(ALL_GATED)})")
        return 2
    return run_gates(required)


if __name__ == "__main__":
    sys.exit(main())
