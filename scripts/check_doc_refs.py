"""Docs integrity check: every internal link, repo path, serve-CLI verb,
and bench-json filename referenced by the maintained docs must exist.

    python -m scripts.check_doc_refs

Checked documents: README.md, docs/ARCHITECTURE.md, docs/OPERATIONS.md,
docs/BENCHMARKS.md (plus any extra paths passed as argv). Four kinds of
references are verified:

- markdown link targets ``[text](target)`` — external schemes
  (http/https/mailto) and pure in-page anchors are skipped; relative
  targets resolve against the containing document's directory, anchors
  stripped;
- path-shaped inline code spans ```like/this.py``` — a span counts as a
  path when it contains a ``/``, is made of plain path characters (no
  spaces, globs, placeholders, or call syntax), and ends in a known text/
  code extension or lives under a known top-level directory. Module
  dotted names (``repro.core.policy``), CLI snippets, and ``<name>``
  templates are deliberately not matched;
- serve CLI verbs — a ``python -m repro.launch.serve <verb>`` invocation
  (in a code block) or a ```serve <verb>``` inline span must name a verb
  from the REAL argparse registry, read by AST-parsing the module-level
  ``VERBS``/``WORKER_VERBS`` tuples out of ``src/repro/launch/serve.py``
  (the docs CI job installs no dependencies, so nothing is imported);
  ``serve workers <sub>`` additionally validates the sub-verb. The
  legacy flat form (flags directly after the module) is skipped;
- bench json filenames — every literal ``BENCH_<name>.json`` mention
  must exist at the repo root (``<name>`` templates do not match the
  literal pattern and are skipped).

Exit status 1 with a per-reference listing when anything dangles, so CI
fails the docs job instead of shipping broken links.
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DOCS = ("README.md", "docs/ARCHITECTURE.md", "docs/OPERATIONS.md",
        "docs/BENCHMARKS.md")
SERVE_SRC = REPO / "src" / "repro" / "launch" / "serve.py"

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_CODE = re.compile(r"`([^`\n]+)`")
# plain path characters only: letters/digits . _ - / (no spaces, globs,
# angle brackets, parens, colons)
_PATHISH = re.compile(r"^[A-Za-z0-9_.\-/]+$")
_EXTS = (".py", ".md", ".json", ".toml", ".yml", ".yaml", ".txt", ".cfg")
_TOP_DIRS = ("src", "tests", "benchmarks", "examples", "docs", "scripts",
             ".github")
# `python -m repro.launch.serve <verb> [<sub>]`, tolerating one
# backslash-newline continuation before each token. Tokens exclude
# backticks/backslashes so span-final verbs don't swallow the closer.
_SERVE_CLI = re.compile(
    r"-m\s+repro\.launch\.serve"
    r"(?:[ \t]*\\\n)?[ \t]+([^\s`\\]+)"
    r"(?:(?:[ \t]*\\\n)?[ \t]+([^\s`\\]+))?")
# inline spans like `serve drain` / `serve workers status --json`
_SERVE_SPAN = re.compile(r"^serve\s+([a-z][\w|-]*)(?:\s+([a-z][\w-]*))?")
_BENCH_JSON = re.compile(r"BENCH_\w+\.json")

_REGISTRY = None


def serve_verb_registry():
    """(VERBS, WORKER_VERBS) from the serve CLI's argparse registry.

    AST-parses the module-level tuple assignments out of
    ``src/repro/launch/serve.py`` instead of importing it: the CI docs
    job runs on a bare interpreter with no dependencies installed, and
    serve.py's verb handlers pull in the whole serving stack.
    ``tests/test_check_doc_refs.py`` asserts these tuples match the live
    module, so the parse cannot silently drift from the real CLI.
    """
    global _REGISTRY
    if _REGISTRY is None:
        tree = ast.parse(SERVE_SRC.read_text(encoding="utf-8"))
        found = {}
        for node in tree.body:
            if (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and node.targets[0].id in ("VERBS", "WORKER_VERBS")):
                found[node.targets[0].id] = tuple(
                    ast.literal_eval(node.value))
        if set(found) != {"VERBS", "WORKER_VERBS"}:
            raise RuntimeError(
                f"could not AST-parse VERBS/WORKER_VERBS from {SERVE_SRC}")
        _REGISTRY = (found["VERBS"], found["WORKER_VERBS"])
    return _REGISTRY


def _verb_error(verb: str, sub, verbs, worker_verbs):
    """-> error string for one doc-mentioned (verb, sub) pair, or None.

    ``verb`` may be pipe-joined shorthand (``cancel|pause|resume``);
    every alternative must be registered. A flag-shaped ``sub`` is not a
    sub-verb and is ignored.
    """
    for v in verb.split("|"):
        if v not in verbs:
            return f"unknown serve verb '{v}' (known: {', '.join(verbs)})"
    if verb == "workers" and sub and not sub.startswith("-"):
        if sub not in worker_verbs:
            return (f"unknown serve workers sub-verb '{sub}' "
                    f"(known: {', '.join(worker_verbs)})")
    return None


def _iter_verb_errors(text: str):
    verbs, worker_verbs = serve_verb_registry()
    for m in _SERVE_CLI.finditer(text):
        verb, sub = m.group(1), m.group(2)
        if verb.startswith(("-", "<")):
            continue  # flat form (flags first) or a <verb> placeholder
        err = _verb_error(verb, sub, verbs, worker_verbs)
        if err:
            yield f"`-m repro.launch.serve {verb}`", err
    for m in _CODE.finditer(text):
        span = m.group(1)
        if "repro.launch.serve" in span:
            continue  # already covered by the CLI pattern above
        sm = _SERVE_SPAN.match(span)
        if not sm:
            continue
        err = _verb_error(sm.group(1), sm.group(2), verbs, worker_verbs)
        if err:
            yield f"`{span}`", err


def _iter_link_targets(text: str):
    for m in _LINK.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        yield m.group(0), target.split("#", 1)[0]


def _iter_code_paths(text: str):
    for m in _CODE.finditer(text):
        span = m.group(1)
        # strip a trailing ::Symbol qualifier (module path still checked)
        path = span.split("::", 1)[0]
        if "/" not in path or not _PATHISH.match(path):
            continue
        if not (path.endswith(_EXTS)
                or path.split("/", 1)[0] in _TOP_DIRS):
            continue
        yield f"`{span}`", path


def check_document(doc: Path):
    """-> list of (reference, resolved_path) that do not exist."""
    text = doc.read_text(encoding="utf-8")
    missing = []
    for ref, target in _iter_link_targets(text):
        if not target:
            continue
        resolved = (doc.parent / target).resolve()
        if not resolved.exists():
            missing.append((ref, target))
    for ref, path in _iter_code_paths(text):
        if not (REPO / path).exists():
            missing.append((ref, path))
    for ref, err in _iter_verb_errors(text):
        missing.append((ref, err))
    for name in sorted(set(_BENCH_JSON.findall(text))):
        if not (REPO / name).exists():
            missing.append((f"`{name}`", f"{name} not at repo root"))
    return missing


def main(argv=None) -> int:
    docs = [REPO / d for d in DOCS]
    # argv=[] must mean "no extra documents", not "fall back to CLI args"
    docs += [Path(p) for p in (sys.argv[1:] if argv is None else argv)]
    failures = 0
    for doc in docs:
        if not doc.exists():
            print(f"MISSING DOCUMENT: {doc}")
            failures += 1
            continue
        missing = check_document(doc)
        rel = doc.relative_to(REPO) if doc.is_relative_to(REPO) else doc
        if missing:
            failures += len(missing)
            for ref, target in missing:
                print(f"{rel}: dangling reference {ref} -> {target}")
        else:
            print(f"{rel}: OK")
    if failures:
        print(f"\n{failures} dangling reference(s)")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
