"""Docs integrity check: every internal link and repo path referenced by
the maintained docs must exist.

    python -m scripts.check_doc_refs

Checked documents: README.md, docs/ARCHITECTURE.md (plus any extra paths
passed as argv). Two kinds of references are verified against the
repository tree:

- markdown link targets ``[text](target)`` — external schemes
  (http/https/mailto) and pure in-page anchors are skipped; relative
  targets resolve against the containing document's directory, anchors
  stripped;
- path-shaped inline code spans ```like/this.py``` — a span counts as a
  path when it contains a ``/``, is made of plain path characters (no
  spaces, globs, placeholders, or call syntax), and ends in a known text/
  code extension or lives under a known top-level directory. Module
  dotted names (``repro.core.policy``), CLI snippets, and ``<name>``
  templates are deliberately not matched.

Exit status 1 with a per-reference listing when anything dangles, so CI
fails the docs job instead of shipping broken links.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DOCS = ("README.md", "docs/ARCHITECTURE.md")

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_CODE = re.compile(r"`([^`\n]+)`")
# plain path characters only: letters/digits . _ - / (no spaces, globs,
# angle brackets, parens, colons)
_PATHISH = re.compile(r"^[A-Za-z0-9_.\-/]+$")
_EXTS = (".py", ".md", ".json", ".toml", ".yml", ".yaml", ".txt", ".cfg")
_TOP_DIRS = ("src", "tests", "benchmarks", "examples", "docs", "scripts",
             ".github")


def _iter_link_targets(text: str):
    for m in _LINK.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        yield m.group(0), target.split("#", 1)[0]


def _iter_code_paths(text: str):
    for m in _CODE.finditer(text):
        span = m.group(1)
        # strip a trailing ::Symbol qualifier (module path still checked)
        path = span.split("::", 1)[0]
        if "/" not in path or not _PATHISH.match(path):
            continue
        if not (path.endswith(_EXTS)
                or path.split("/", 1)[0] in _TOP_DIRS):
            continue
        yield f"`{span}`", path


def check_document(doc: Path):
    """-> list of (reference, resolved_path) that do not exist."""
    text = doc.read_text(encoding="utf-8")
    missing = []
    for ref, target in _iter_link_targets(text):
        if not target:
            continue
        resolved = (doc.parent / target).resolve()
        if not resolved.exists():
            missing.append((ref, target))
    for ref, path in _iter_code_paths(text):
        if not (REPO / path).exists():
            missing.append((ref, path))
    return missing


def main(argv=None) -> int:
    docs = [REPO / d for d in DOCS]
    # argv=[] must mean "no extra documents", not "fall back to CLI args"
    docs += [Path(p) for p in (sys.argv[1:] if argv is None else argv)]
    failures = 0
    for doc in docs:
        if not doc.exists():
            print(f"MISSING DOCUMENT: {doc}")
            failures += 1
            continue
        missing = check_document(doc)
        rel = doc.relative_to(REPO) if doc.is_relative_to(REPO) else doc
        if missing:
            failures += len(missing)
            for ref, target in missing:
                print(f"{rel}: dangling reference {ref} -> {target}")
        else:
            print(f"{rel}: OK")
    if failures:
        print(f"\n{failures} dangling reference(s)")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
